"""Multi-device numeric equality for the §Perf sharded code paths.

The shard_map MoE dispatch/combine (`moe._shmap_rows`), the context-
sharded ring-buffer KV insert (`layers._cache_update_sharded`) and the
one-block decode path must produce bit-identical results to the plain
single-device path.  The main test process keeps the spec-mandated single
CPU device, so the real multi-device checks run in a subprocess with
``xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(body: str):
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models.common import Rules
        assert len(jax.devices()) == 8, jax.devices()
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=REPO, timeout=600,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


@pytest.mark.slow
def test_moe_shard_map_dispatch_matches_single_device():
    """moe_mlp under 8-device serve rules == moe_mlp with no rules."""
    _run_subprocess(
        """
        from repro.configs import registry
        from repro.models import moe
        from repro.launch.shardings import serve_rules, moe_dp_compute

        cfg = registry.get_smoke_config("qwen3-moe-30b-a3b").with_(
            num_instances=2, dtype="float32", param_dtype="float32")
        key = jax.random.PRNGKey(0)
        params = moe.init(cfg, key)
        lp = jax.tree.map(lambda x: x[0], params["layers"])  # one layer
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16, cfg.d_model))

        ref, aux_ref = moe.moe_mlp(cfg, lp, x)               # no rules: plain vmap

        for make in (serve_rules, lambda m: moe_dp_compute(serve_rules(m))):
            rules = make(mesh)
            with jax.set_mesh(mesh), rules:
                out, aux = jax.jit(lambda l, xx: moe.moe_mlp(cfg, l, xx))(lp, x)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)
            np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)
        print("moe shard_map OK")
        """
    )


@pytest.mark.slow
def test_moe_ep_shmap_matches_single_device():
    """Canonical-EP path (expert-window dispatch + psum combine) == plain
    path, experts sharded 4-way over 'model'."""
    _run_subprocess(
        """
        from repro.configs import registry
        from repro.models import moe
        from repro.launch.shardings import serve_rules, moe_ep_shmap

        # 8 experts on a 4-way model axis -> e_local = 2 per rank
        cfg = registry.get_smoke_config("qwen3-moe-30b-a3b").with_(
            num_instances=2, num_experts=8, num_experts_per_tok=2,
            dtype="float32", param_dtype="float32")
        params = moe.init(cfg, jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda x: x[0], params["layers"])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16, cfg.d_model))

        ref, aux_ref = moe.moe_mlp(cfg, lp, x)

        rules = moe_ep_shmap(serve_rules(mesh))
        with jax.set_mesh(mesh), rules:
            out, aux = jax.jit(lambda l, xx: moe.moe_mlp(cfg, l, xx))(lp, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)

        # gradients flow through the shard_map + psum
        def loss(l, xx):
            o, a = moe.moe_mlp(cfg, l, xx)
            return jnp.sum(o * o) + a
        with jax.set_mesh(mesh), rules:
            g = jax.jit(jax.grad(loss))(lp, x)
        g_ref = jax.grad(loss)(lp, x)
        for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=5e-4)
        print("moe ep shard_map OK")
        """
    )


@pytest.mark.slow
def test_sharded_cache_insert_matches_single_device():
    """_cache_update_sharded == plain vmap DUS, cache_seq sharded 4-way."""
    _run_subprocess(
        """
        from repro.models import layers as L
        from repro.launch.shardings import serve_rules

        m, b, s, kvh, hd = 2, 4, 32, 2, 8
        key = jax.random.PRNGKey(0)
        ck = jax.random.normal(key, (m, b, s, kvh, hd))
        cv = jax.random.normal(jax.random.PRNGKey(1), (m, b, s, kvh, hd))
        kn = jax.random.normal(jax.random.PRNGKey(2), (m, b, 1, kvh, hd))
        vn = jax.random.normal(jax.random.PRNGKey(3), (m, b, 1, kvh, hd))
        # positions straddling shard boundaries (s_local = 8)
        pos = jnp.array([[0, 7, 8, 31], [15, 16, 23, 24]], jnp.int32)

        rk, rv = L.cache_update_one(ck, cv, kn, vn, pos)      # no rules

        rules = serve_rules(mesh)
        with jax.set_mesh(mesh), rules:
            sk, sv = jax.jit(L.cache_update_one)(ck, cv, kn, vn, pos)
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(rk))
        np.testing.assert_array_equal(np.asarray(sv), np.asarray(rv))
        print("cache insert OK")
        """
    )


@pytest.mark.slow
def test_decode_step_sharded_matches_single_device():
    """Full dense decode_step (one-block attention + sharded cache) under
    the 8-device serve rules == single-device decode_step."""
    _run_subprocess(
        """
        from repro import api
        from repro.configs import registry
        from repro.launch.shardings import serve_rules

        cfg = registry.get_smoke_config("tinyllama-1.1b").with_(
            num_instances=2, dtype="float32", param_dtype="float32")
        params = api.init(cfg, jax.random.PRNGKey(0))
        ctx = 64
        cache = api.make_cache(cfg, 2, 4, ctx)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 4, 1), 0, cfg.vocab_size)
        pos = jnp.full((2, 4), 17, jnp.int32)

        ref_logits, ref_cache = api.decode_step(cfg, params, cache, toks, pos)

        rules = serve_rules(mesh)
        with jax.set_mesh(mesh), rules:
            out_logits, out_cache = jax.jit(
                lambda p, c, t, q: api.decode_step(cfg, p, c, t, q)
            )(params, cache, toks, pos)
        np.testing.assert_allclose(np.asarray(out_logits),
                                   np.asarray(ref_logits), rtol=2e-5, atol=2e-5)
        for a, bnd in zip(jax.tree.leaves(out_cache), jax.tree.leaves(ref_cache)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(bnd),
                                       rtol=2e-5, atol=2e-5)
        print("decode step OK")
        """
    )


@pytest.mark.slow
def test_flash_attention_shard_map_prefill_matches():
    """Sq>1 attention under serve rules (shard_map over q-heads) == plain
    single-device flash, for both KVH-divisible and GQA-sliced layouts."""
    _run_subprocess(
        """
        from repro.models import layers as L
        from repro.launch.shardings import serve_rules

        def run(h, kvh):
            m, b, sq, skv, hd = 2, 4, 32, 64, 8
            q = jax.random.normal(jax.random.PRNGKey(0), (m, b, sq, h, hd))
            k = jax.random.normal(jax.random.PRNGKey(1), (m, b, skv, kvh, hd))
            v = jax.random.normal(jax.random.PRNGKey(2), (m, b, skv, kvh, hd))
            qp = jnp.broadcast_to(jnp.arange(32, 32 + sq, dtype=jnp.int32), (m, b, sq))
            kp = jnp.broadcast_to(jnp.arange(skv, dtype=jnp.int32), (m, b, skv))
            ref = L.flash_attention(q, k, v, qp, kp, q_chunk=16, kv_chunk=16)
            rules = serve_rules(mesh)   # model axis = 4
            with jax.set_mesh(mesh), rules:
                out = jax.jit(lambda *a: L.flash_attention(
                    *a, q_chunk=16, kv_chunk=16))(q, k, v, qp, kp)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)

        run(h=8, kvh=8)   # kvh divides model axis (4): fully local heads
        run(h=8, kvh=2)   # kvh=2 < 4: per-rank GQA kv-head slice path
        run(h=6, kvh=2)   # h%4 != 0: falls back to the GSPMD path
        print("flash shard_map OK")
        """
    )


def test_flash_attention_single_block_decode_path():
    """sq=1 takes the one-block path (kc == skv) and matches the chunked
    reference numerically (single device, no rules needed)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models import layers as L

    m, b, h, kvh, hd, skv = 2, 3, 4, 2, 8, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (m, b, 1, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (m, b, skv, kvh, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (m, b, skv, kvh, hd))
    q_pos = jnp.full((m, b, 1), 40, jnp.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(skv, dtype=jnp.int32), (m, b, skv))

    out = L.flash_attention(q, k, v, q_pos, kv_pos)            # one-block path
    # reference: force chunked streaming by faking sq=2 with a dup query
    q2 = jnp.concatenate([q, q], axis=2)
    qp2 = jnp.concatenate([q_pos, q_pos], axis=2)
    ref = L.flash_attention(q2, k, v, qp2, kv_pos, kv_chunk=16)[:, :, :1]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
