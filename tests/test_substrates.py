"""Substrate tests: optimizer, schedules, data pipeline, checkpointing,
train loop convergence, serving engine end-to-end."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.configs import registry
from repro.data import pipeline
from repro.optim import adamw_init, adamw_update, cosine_with_warmup
from repro.train import loop as train_loop_mod
from repro.serving import MultiModelServer, Request


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw_update(g, opt, params, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_cosine_schedule_shape():
    f = cosine_with_warmup(1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(f(jnp.int32(s))) for s in (0, 9, 10, 50, 100)]
    assert lrs[0] < lrs[1] <= lrs[2]
    assert lrs[2] >= lrs[3] >= lrs[4]
    assert lrs[4] >= 1e-4 * 0.99


def test_synthetic_data_deterministic_and_per_instance():
    d = pipeline.SyntheticLM(vocab_size=100, num_instances=3, seed=1)
    b1 = d.batch(0, 2, 16)
    b2 = d.batch(0, 2, 16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    # different instances see different streams
    assert not np.array_equal(np.asarray(b1["tokens"][0]), np.asarray(b1["tokens"][1]))
    # labels are next-token shifted
    d1 = pipeline.SyntheticLM(vocab_size=100, num_instances=1, seed=2)
    b = d1.batch(3, 1, 8)
    assert b["tokens"].shape == (1, 1, 8) and b["labels"].shape == (1, 1, 8)


def test_memmap_data_roundtrip(tmp_path):
    toks = np.arange(10_000) % 97
    p = tmp_path / "shard0.bin"
    pipeline.write_token_file(p, toks)
    d = pipeline.MemmapLM([str(p)], num_instances=2, seed=0)
    b = d.batch(0, 2, 32)
    assert b["tokens"].shape == (2, 2, 32)
    assert int(b["tokens"].max()) < 97


def test_checkpoint_roundtrip(tmp_path):
    from repro import checkpoint as ckpt
    cfg = registry.get_smoke_config("tinyllama-1.1b")
    params = api.init(cfg, jax.random.PRNGKey(0))
    ckpt.save(tmp_path / "step0", params, extra={"step": 0})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    back = ckpt.restore(tmp_path / "step0", like)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        params, back,
    )


def test_checkpoint_rejects_shape_mismatch(tmp_path):
    from repro import checkpoint as ckpt
    tree = {"a": jnp.zeros((2, 3))}
    ckpt.save(tmp_path / "c", tree)
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path / "c", {"a": jax.ShapeDtypeStruct((3, 2), jnp.float32)})


def test_train_loop_loss_decreases():
    """A few hundred steps on a tiny model must cut the loss well below
    the uniform baseline (ln V)."""
    cfg = registry.get_smoke_config("tinyllama-1.1b").with_(vocab_size=64)
    data = pipeline.SyntheticLM(cfg.vocab_size, 1, seed=0)
    sched = cosine_with_warmup(3e-3, 10, 200)
    state, losses = train_loop_mod.train_loop(
        cfg, data, steps=60, batch_size=4, seq_len=32,
        lr_schedule=sched, log_every=20, print_fn=lambda *_: None,
    )
    first, last = losses[0][1], losses[-1][1]
    assert last < first - 0.2, (first, last)


def test_serving_engine_end_to_end():
    """NetFuse-merged serving: M=2 instances, different queues, slot reuse;
    outputs must equal per-instance (unmerged) greedy decoding."""
    cfg = registry.get_smoke_config("tinyllama-1.1b").with_(num_instances=2)
    params = api.init(cfg, jax.random.PRNGKey(0))
    server = MultiModelServer(
        cfg, params, slots_per_instance=2, max_context=64, temperature=0.0
    )
    reqs = [
        Request(instance=0, prompt=[1, 2, 3], max_new_tokens=5),
        Request(instance=1, prompt=[4, 5], max_new_tokens=5),
        Request(instance=0, prompt=[7, 8, 9, 10], max_new_tokens=4),
        Request(instance=1, prompt=[3, 3, 3], max_new_tokens=4),
        Request(instance=0, prompt=[2, 2], max_new_tokens=3),  # 3rd req, forces slot reuse
    ]
    ids = [server.submit(r) for r in reqs]
    results = {r.request_id: r for r in server.run_until_drained()}
    assert set(results) == set(ids)

    # oracle: per-instance greedy decode with the unmerged model
    from repro.models import common as C, dense
    ax = dense.axes(cfg)
    for req, rid in zip(reqs, ids):
        pi = C.take_instance(params, ax, req.instance)
        toks = list(req.prompt)
        out = []
        for _ in range(req.max_new_tokens):
            logits = dense.forward(cfg, pi, jnp.asarray(toks, jnp.int32)[None, None])
            nxt = int(jnp.argmax(logits[0, 0, -1]))
            out.append(nxt)
            toks.append(nxt)
        assert results[rid].tokens == out, (rid, results[rid].tokens, out)
